//! Control-plane integration tests: full-fleet determinism, the host
//! budget invariant, the SLA arbitration property, pool-partition
//! plumbing and the release-recovery boost (randomized where useful,
//! driven by the crate's own deterministic RNG — failures print the
//! offending seed).

use flexswap::config::{ArbiterKind, ControlConfig, HostConfig, MmConfig};
use flexswap::coordinator::Machine;
use flexswap::daemon::{Arbiter, Daemon, Sla, VmRegistration, VmReport};
use flexswap::harness::fleet::{recovery_release, run_fleet};
use flexswap::sim::Rng;
// Trait in scope for the `machine.backend.*` probes below (latent PR 3
// omission, surfaced by the first toolchain-bearing CI run).
use flexswap::storage::SwapBackend;
use flexswap::types::MS;
use flexswap::workloads::UniformRandom;

/// Satellite: same-seed determinism across a full 64-VM fleet run, and
/// the acceptance invariant — Σ(resident + pool) never exceeds the
/// configured host budget at any control tick.
#[test]
fn fleet_determinism_and_budget_invariant() {
    let a = run_fleet(64, 4_000, ArbiterKind::ProportionalShare, 3);
    let b = run_fleet(64, 4_000, ArbiterKind::ProportionalShare, 3);
    assert_eq!(a, b, "same-seed fleet runs diverged");
    assert_eq!(a.vms, 64);
    assert_eq!(a.total_ops, 64 * 4_000, "fleet did not complete");
    assert!(a.limit_changes > 0, "closed loop never acted");
    assert_eq!(a.budget_exceeded_ticks, 0, "budget exceeded: {a:?}");
    assert!(a.min_headroom_bytes >= 0, "negative headroom: {a:?}");

    // Static limits obey the invariant too (shares are budget-derived).
    let s = run_fleet(64, 4_000, ArbiterKind::Static, 3);
    assert_eq!(s.budget_exceeded_ticks, 0, "static fleet exceeded: {s:?}");
}

/// Arbitration property (randomized): the proportional solver never
/// hands out more than the usable budget, and never squeezes a Gold VM
/// below its reported WSS while any Bronze VM still has reclaimable
/// slack (limit above its floor).
#[test]
fn arbitration_property_gold_floor_and_budget() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed * 13 + 1);
        let n = 2 + rng.below(14) as usize;
        let mut reports = Vec::new();
        for vm in 0..n {
            let sla = [Sla::Gold, Sla::Silver, Sla::Bronze][rng.below(3) as usize];
            let usage = (1 + rng.below(256)) << 20; // up to 256MB
            let wss = usage / (1 + rng.below(4));
            reports.push(VmReport {
                vm,
                sla,
                usage_bytes: usage,
                wss_bytes: wss,
                cold_estimate_bytes: usage - wss,
                pf_count: 0,
                pf_delta: 0,
                limit_bytes: Some(usage),
                unit_bytes: if rng.chance(0.5) { 4096 } else { 2 << 20 },
                inflight_allowance: 4 * 4096,
            });
        }
        let total_demand: u64 = reports.iter().map(Arbiter::demand_of).sum();
        // Sweep from starvation to surplus.
        for frac in [10u64, 40, 80, 120] {
            let usable = total_demand / 100 * frac;
            let mut arb = Arbiter::new(ArbiterKind::ProportionalShare);
            let limits = arb.proportional_limits(&reports, usable).to_vec();
            assert!(
                limits.iter().sum::<u64>() <= usable,
                "seed {seed} frac {frac}: over budget"
            );
            let bronze_has_slack = reports.iter().enumerate().any(|(i, r)| {
                r.sla == Sla::Bronze && limits[i] > Arbiter::floor_of(r)
            });
            for (i, r) in reports.iter().enumerate() {
                if r.sla == Sla::Gold && limits[i] < r.wss_bytes {
                    assert!(
                        !bronze_has_slack,
                        "seed {seed} frac {frac}: gold {i} below WSS \
                         while bronze has slack: {limits:?}"
                    );
                }
            }
        }
    }
}

/// Acceptance: the closed loop beats static limits on at least one of
/// memory saved / p99 fault stall on the same fleet.
#[test]
fn closed_loop_beats_static_on_density_or_p99() {
    let st = run_fleet(48, 10_000, ArbiterKind::Static, 7);
    let cl = run_fleet(48, 10_000, ArbiterKind::ProportionalShare, 7);
    assert_eq!(st.total_ops, cl.total_ops);
    let saved_win = cl.saved_frac > st.saved_frac;
    let p99_win = cl.p99_stall_ns < st.p99_stall_ns;
    assert!(
        saved_win || p99_win,
        "closed loop won on neither axis: static {st:?} vs closed {cl:?}"
    );
}

/// Acceptance: fig13-style recovery after a hard-limit release with the
/// recovery-boost hint is no slower than without it (and converts major
/// faults into prefetched minors).
#[test]
fn recovery_boost_is_no_slower() {
    let plain = recovery_release(false, 120_000, 11);
    let boosted = recovery_release(true, 120_000, 11);
    assert!(
        boosted.prefetch_issued > plain.prefetch_issued,
        "boost issued nothing extra: {boosted:?} vs {plain:?}"
    );
    assert!(
        boosted.majors <= plain.majors,
        "boost increased majors: {boosted:?} vs {plain:?}"
    );
    assert!(
        boosted.after_lift_ns <= plain.after_lift_ns,
        "boost recovery slower: {boosted:?} vs {plain:?}"
    );
}

/// Pool-partition plumbing end to end: daemon registration assigns SLA
/// classes, `install_control` pushes the quota split, and per-class
/// occupancy stays within quota while summing to the pool total.
#[test]
fn daemon_fleet_partitions_pool_by_sla() {
    let host = HostConfig::default(); // compressed pool enabled
    let cap = host.tier.pool_capacity_bytes;
    let ctrl = ControlConfig {
        pool_split_pct: [20, 30, 50],
        ..Default::default()
    };
    let mut d = Daemon::with_control(host, ctrl);
    for (i, sla) in [Sla::Gold, Sla::Silver, Sla::Bronze].iter().enumerate() {
        d.register(VmRegistration {
            name: format!("vm{i}"),
            frames: 8192,
            vcpus: 1,
            sla: *sla,
            workloads: vec![Box::new(UniformRandom::new(0, 4096, 60_000))],
            // A tight limit on the 4k-unit Bronze VM forces swap
            // traffic through its pool partition; the huge-unit VMs
            // run unlimited (a 4MB limit on 2MB units would thrash).
            initial_limit_bytes: if *sla == Sla::Bronze {
                Some(1024 * 4096)
            } else {
                None
            },
        });
    }
    let res = d.machine.run();
    assert_eq!(res.len(), 3);
    let quotas = [cap / 100 * 20, cap / 100 * 30, cap / 100 * 50];
    let mut sum = 0;
    for c in 0..3u8 {
        let bytes = d.machine.backend.class_pool_bytes(c);
        assert!(
            bytes <= quotas[c as usize],
            "class {c} over quota: {bytes} > {}",
            quotas[c as usize]
        );
        sum += bytes;
    }
    assert_eq!(sum, d.machine.backend.metrics().pool_bytes);
    // The Bronze (4k, aggressive) VM definitely produced pool stores.
    assert!(
        d.machine.backend.metrics().pool_stores > 0,
        "no pool traffic at all"
    );
}

/// The migrated one-shot path: a scheduled limit change applies from a
/// control tick at exactly its virtual time, without a periodic chain.
#[test]
fn scheduled_limit_applies_in_loop() {
    let mut m = Machine::new(HostConfig::default());
    let mm_cfg = MmConfig { scan_interval: 3600 * flexswap::types::SEC, ..Default::default() };
    let vmid = m.sys_vm(
        flexswap::config::VmConfig {
            frames: 4096,
            vcpus: 1,
            page_size: flexswap::types::PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        },
        &mm_cfg,
        vec![Box::new(UniformRandom::new(0, 2048, 100_000))],
    );
    m.schedule_limit(vmid, 50 * MS, Some(512 * 4096));
    let res = m.run();
    assert_eq!(res[0].work_ops, 100_000);
    let mm = m.mm(vmid).unwrap();
    assert_eq!(mm.core.limit_units, Some(512));
    assert!(res[0].counters.swapout_ops > 0, "limit never bit");
    assert!(
        mm.core.usage_units <= 512 + mm.swapper.threads() as u64,
        "limit not enforced: {}",
        mm.core.usage_units
    );
}
