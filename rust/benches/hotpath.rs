//! Hot-path microbenchmarks: the components on (or near) the page-fault
//! path, plus the analytics backends (native vs XLA artifact ablation).
//!
//! Run: `cargo bench --bench hotpath`
//!
//! Besides the console table, results are written to
//! `BENCH_hotpath.json` at the repo root so the bench trajectory is
//! tracked across PRs (schema: flexswap-bench-v1).

mod common;

use common::{bench, BenchResult};
use flexswap::config::{HwConfig, MmConfig, SwCost};
use flexswap::mm::queues::QueueClass;
use flexswap::mm::Mm;
use flexswap::policies::analytics::{ColdAnalytics, NativeAnalytics};
use flexswap::sim::Rng;
use flexswap::types::Bitmap;
use flexswap::uffd::UffdEvent;
use flexswap::vm::FaultInfo;

fn fault_ev(unit: u64) -> UffdEvent {
    UffdEvent {
        fault: FaultInfo {
            unit,
            gpa_frame: unit,
            gva_page: unit,
            cr3: 0x1000,
            ip: 0x400000,
            write: false,
            vcpu: 0,
            pre_cost: 0,
        },
        raised_at: 0,
        delivered_at: 0,
    }
}

fn main() {
    println!("== flexswap hot-path microbenchmarks ==\n");
    let mut results: Vec<BenchResult> = Vec::new();

    // Swapper queue ops: push+pop with conflation checks.
    {
        let mut q = flexswap::mm::SwapperQueue::new(65_536);
        let mut i = 0u64;
        results.push(bench("swapper_queue push+pop", 200_000, || {
            q.push(i % 65_536, QueueClass::Fault);
            q.pop(false);
            i += 1;
        }));
    }

    // Policy-engine fault handling (no policies) — the critical path.
    {
        let vm_cfg = flexswap::config::VmConfig {
            frames: 65_536,
            vcpus: 1,
            page_size: flexswap::types::PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(1);
        let mut vm = flexswap::vm::Vm::new(
            &vm_cfg,
            &HwConfig::default(),
            &SwCost::default(),
            &mut rng,
        );
        let mut mm = Mm::new(&MmConfig::default(), 65_536, 4096, &SwCost::default(), 0);
        let mut i = 0u64;
        results.push(bench("policy_engine on_fault + pick_work", 100_000, || {
            let u = i % 65_536;
            mm.on_fault(&vm, &fault_ev(u), i);
            if mm.pick_work(i).is_some() {
                let _ = mm.finish_swapin(&mut vm, u, false, i);
            }
            i += 1;
        }));
    }

    // TLB access path.
    {
        let mut tlb = flexswap::hw::Tlb::new(1536);
        let mut rng = Rng::new(2);
        results.push(bench("tlb access (miss-heavy)", 500_000, || {
            tlb.access(1, rng.below(1 << 22), &mut rng);
        }));
    }

    // EPT scan of 64k units.
    {
        let mut ept = flexswap::hw::Ept::new(65_536);
        for u in 0..65_536 {
            ept.map(u);
        }
        let mut bm = Bitmap::new(65_536);
        results.push(bench("ept scan_and_clear (64k units)", 2_000, || {
            bm.zero();
            ept.scan_and_clear(&mut bm);
        }));
    }

    // EPT scan with every region 2MB-backed: the scanner tests one
    // summary bit per live region (128 here) instead of 64k unit PTEs —
    // the PR 8 granularity win the acceptance gate pins at >=4x.
    {
        let mut ept = flexswap::hw::Ept::new(65_536);
        for r in 0..65_536 / flexswap::types::REGION_UNITS {
            ept.set_region_huge(r);
            ept.map(r * flexswap::types::REGION_UNITS);
        }
        let mut bm = Bitmap::new(65_536);
        results.push(bench("ept scan_and_clear (huge)", 2_000, || {
            bm.zero();
            ept.scan_and_clear(&mut bm);
        }));
    }

    // Analytics ablation: native vs XLA artifact over H=32, N=65536.
    {
        let mut rng = Rng::new(3);
        let hist: Vec<Bitmap> = (0..32)
            .map(|_| {
                let mut b = Bitmap::new(65_536);
                for u in 0..65_536 {
                    if rng.chance(0.3) {
                        b.set(u);
                    }
                }
                b
            })
            .collect();
        let hist_refs: Vec<&Bitmap> = hist.iter().collect();
        let mut nat = NativeAnalytics::new();
        results.push(bench("dt_reclaim analytics native (64k units)", 20, || {
            let _ = nat.dt_reclaim(&hist_refs, 0.02, 5.0);
        }));
        match flexswap::runtime::XlaAnalytics::from_artifacts("artifacts") {
            Ok(mut x) => {
                results.push(bench("dt_reclaim analytics xla-pjrt (64k units)", 20, || {
                    let _ = x.dt_reclaim(&hist_refs, 0.02, 5.0);
                }));
            }
            Err(e) => println!("(xla analytics skipped: {e})"),
        }
    }

    // Storage tiers: the codec and the tiered backend's hot operations
    // (the `storage_tiers` series tracked from PR 2 onward).
    {
        use flexswap::config::TierConfig;
        use flexswap::hw::Nvme;
        use flexswap::storage::{SwapBackend, TierHint, TieredBackend};

        let sw = SwCost::default();
        let hw = HwConfig::default();

        // Run-structured 4k page (the pool's common case).
        let mut page = vec![0u8; 4096];
        for i in (0..4096).step_by(512) {
            page[i] = (i / 512) as u8;
        }
        results.push(bench("storage_tiers codec compress 4k (pattern)", 100_000, || {
            let _ = flexswap::storage::compress(&page);
        }));

        let mut nvme = Nvme::new(&hw);

        // Pool store + decompress-on-hit round trip.
        {
            let mut b = TieredBackend::new(&TierConfig::default(), &sw);
            let mut rng = Rng::new(7);
            let mut out = Vec::new();
            let mut i = 0u64;
            results.push(bench("storage_tiers pool write+read hit (4k)", 50_000, || {
                let u = i % 4096;
                b.write(0, u, &page, TierHint::Auto, i, &mut nvme, &mut rng);
                b.read(0, u, 4096, &mut out, i, &mut nvme, &mut rng);
                i += 1;
            }));
        }

        // Remote-tier hit: decompress from a leased donor's DRAM (PR
        // 9). The modeled network round trip is virtual time — wall
        // cost is the lookup + decompress, tracked so the remote read
        // path never silently grows real CPU work.
        {
            let mut b = TieredBackend::new(&TierConfig::default(), &sw);
            let mut rng = Rng::new(12);
            for u in 0..512u64 {
                b.write(0, u, &page, TierHint::Pool, u, &mut nvme, &mut rng);
            }
            assert!(b.remote_stage(u64::MAX) > 0, "bench staged nothing");
            let mut out = Vec::new();
            let mut i = 0u64;
            results.push(bench("storage_tiers remote hit (4k)", 100_000, || {
                b.read(0, i % 512, 4096, &mut out, i, &mut nvme, &mut rng);
                i += 1;
            }));
        }

        // Golden-image unit install (PR 10): compress, content-hash,
        // and dedup against blobs already stored — the per-unit price
        // `ensure_golden_image` pays once per host when a storm's
        // first clone lands there. Cycling 17 distinct contents makes
        // every install after the first pass a pure dedup hit, the
        // storm's steady state.
        {
            let mut b = TieredBackend::new(&TierConfig::default(), &sw);
            let variants: Vec<Vec<u8>> = (0..17u8)
                .map(|v| {
                    let mut p = page.clone();
                    p[1] = v;
                    p
                })
                .collect();
            let mut i = 0u64;
            results.push(bench("pool dedup store", 100_000, || {
                b.install_image_unit(1, i % 4096, &variants[(i % 17) as usize]);
                i += 1;
            }));
        }

        // Clone-from-image admission hot path (PR 10): attach a clone
        // to the host's golden image (refcount bump + mapping insert)
        // and fault its first boot unit straight out of the dedup'd
        // pool copy — decompress only, no NVMe I/O. This is the
        // per-clone wall cost a boot storm pays at the tick barrier;
        // the ~75 us cold-boot zero-fill it replaces is virtual time.
        {
            let mut b = TieredBackend::new(&TierConfig::default(), &sw);
            for u in 0..512u64 {
                b.install_image_unit(1, u, &page);
            }
            let mut out = Vec::new();
            let mut rng = Rng::new(13);
            let mut i = 0u64;
            results.push(bench("clone admit (image-backed)", 100_000, || {
                let vm = 1 + (i as usize) % 1024;
                b.attach_image(vm, 1);
                b.read(vm, i % 512, 4096, &mut out, i, &mut nvme, &mut rng);
                i += 1;
            }));
        }

        // Sustained watermark writeback churn (sort + coalesce path).
        {
            let cfg = TierConfig {
                pool_capacity_bytes: 64 * 4096,
                reject_pct: 101,
                ..TierConfig::default()
            };
            let mut b = TieredBackend::new(&cfg, &sw);
            let mut rng = Rng::new(8);
            let rnd: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
            let mut j = 0u64;
            results.push(bench("storage_tiers write + watermark drain (4k)", 20_000, || {
                b.write(0, j % 65_536, &rnd, TierHint::Pool, j, &mut nvme, &mut rng);
                j += 1;
            }));
        }

        // Huge-unit direct writeback: one naturally-aligned 2MB NVMe
        // request per reclaim (zero-copy DMA, no bounce buffer) — the
        // per-request path a huge-granularity region reclaim takes.
        {
            let mut b = TieredBackend::flat(&sw);
            let mut rng = Rng::new(9);
            let big = vec![7u8; flexswap::types::HUGE_BYTES as usize];
            let mut k = 0u64;
            results.push(bench("storage_tiers 2M writeback", 50_000, || {
                b.write(0, k % 4096, &big, TierHint::Nvme, k, &mut nvme, &mut rng);
                k += 1;
            }));
        }
    }

    // LRU victim selection under a full resident set.
    {
        let mut core = flexswap::mm::EngineCore::new(65_536, 4096, Some(32_768));
        for u in 0..65_536usize {
            core.states[u] = flexswap::types::UnitState::Resident;
            core.last_touch[u] = u as u64;
        }
        let mut lru = flexswap::policies::LruReclaimer::new();
        use flexswap::mm::LimitReclaimer;
        results.push(bench("lru victim (64k resident)", 20_000, || {
            if let Some(v) = lru.victim(&core, u64::MAX) {
                core.want_out.set(v as usize);
            }
        }));
    }

    // LRU steady state: touches and victims interleaved through the O(1)
    // incremental path (no want_out exhaustion, no rebuilds).
    {
        let mut core = flexswap::mm::EngineCore::new(65_536, 4096, Some(32_768));
        for u in 0..65_536usize {
            core.states[u] = flexswap::types::UnitState::Resident;
            core.last_touch[u] = u as u64;
        }
        let mut lru = flexswap::policies::LruReclaimer::new();
        use flexswap::mm::LimitReclaimer;
        let mut t = 65_536u64;
        let mut rng = Rng::new(4);
        results.push(bench("lru touch+victim steady state", 200_000, || {
            t += 1;
            let u = rng.below(65_536);
            core.last_touch[u as usize] = t;
            lru.touch(u, t);
            if let Some(v) = lru.victim(&core, t) {
                // Re-admit immediately so the resident set stays full.
                core.last_touch[v as usize] = t;
                lru.touch(v, t);
            }
        }));
    }

    // Fleet execution-engine scaling: events/sec of the sharded fleet at
    // 2..64 hosts, sequential merge loop vs parallel epoch engine (PR 6).
    // One timed run per (engine, host-count): `iters` is the total event
    // count (engine-independent for the same seed — the equivalence gate
    // pins that) and `mean_ns` is wall nanoseconds per event, so the
    // seq/par ratio at a host count is the parallel speedup. Tracked as
    // advisory series in `ci/bench_guard.py` (wall-clock scaling depends
    // on the runner's core count).
    {
        use flexswap::config::{FleetConfig, HostConfig, PlacementPolicy};
        use flexswap::daemon::{FleetScheduler, FleetVmSpec, Sla};
        use flexswap::types::{MS, SEC};
        use flexswap::workloads::UniformRandom;
        use std::time::Instant;

        let run_fleet = |hosts: usize, parallel: bool| -> BenchResult {
            let mut f = FleetScheduler::new(
                &HostConfig { seed: 11, ..Default::default() },
                FleetConfig {
                    hosts,
                    host_budgets: vec![24 << 20],
                    placement: PlacementPolicy::SpreadByFaultRate,
                    interval: 5 * MS,
                    max_time: 60 * SEC,
                    parallel,
                    workers: None,
                    ..Default::default()
                },
            );
            for i in 0..hosts * 2 {
                f.admit(FleetVmSpec {
                    name: format!("vm{i}"),
                    sla: Sla::Bronze,
                    frames: 2048,
                    vcpus: 1,
                    workloads: vec![Box::new(UniformRandom::new(0, 1024, 4_000))],
                    initial_limit_bytes: None,
                    mm: None,
                });
            }
            let t0 = Instant::now();
            let _ = f.run();
            let wall_ns = t0.elapsed().as_nanos() as f64;
            let events = f.events_handled().max(1);
            let mean = wall_ns / events as f64;
            BenchResult {
                name: format!(
                    "fleet_scale {} {hosts} hosts",
                    if parallel { "par" } else { "seq" }
                ),
                iters: events,
                mean_ns: mean,
                p50_ns: mean as u64,
                p99_ns: mean as u64,
            }
        };

        println!("\n-- fleet_scale (events/sec, seq vs par) --");
        for hosts in [2usize, 4, 8, 16, 32, 64] {
            let seq = run_fleet(hosts, false);
            let par = run_fleet(hosts, true);
            println!(
                "{:2} hosts: seq {:>12.0} ev/s | par {:>12.0} ev/s | speedup {:.2}x",
                hosts,
                seq.ops_per_sec(),
                par.ops_per_sec(),
                seq.mean_ns / par.mean_ns
            );
            results.push(seq);
            results.push(par);
        }
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_hotpath.json"))
        .unwrap_or_else(|| "BENCH_hotpath.json".into());
    match common::write_json("hotpath", &path, &results) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
