//! Minimal bench harness (criterion is not in the offline crate set):
//! warms up, runs timed iterations, reports mean / p50 / p99 and
//! throughput. Deterministic iteration counts for comparable runs.
//!
//! Shared by multiple bench binaries, each of which uses a subset.
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

impl BenchResult {
    /// Iterations per second implied by the mean.
    pub fn ops_per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }
}

pub fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..iters.div_ceil(10).min(100) {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p99_ns: samples[samples.len() * 99 / 100],
    };
    println!(
        "{:<44} {:>9.0} ns/iter  p50 {:>9} ns  p99 {:>9} ns  ({} iters)",
        r.name, r.mean_ns, r.p50_ns, r.p99_ns, r.iters
    );
    r
}

/// One-shot timing for end-to-end experiment runs.
pub fn bench_once<F: FnOnce() -> u64>(name: &str, f: F) {
    let t0 = Instant::now();
    let rows = f();
    let wall = t0.elapsed().as_secs_f64();
    println!("{:<12} wall {:>8.2}s   ({} result rows)", name, wall, rows);
}

/// Emit results as machine-readable JSON (hand-rolled: serde is not in
/// the offline crate set). Schema `flexswap-bench-v1`: per benchmark
/// name, iteration count, mean/p50/p99 ns and derived ops/s — the
/// bench-trajectory format tracked at the repo root from PR 1 onward.
pub fn write_json(
    bench_name: &str,
    path: &std::path::Path,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"flexswap-bench-v1\",\n");
    s.push_str(&format!("  \"bench\": \"{bench_name}\",\n"));
    s.push_str(&format!(
        "  \"generated_by\": \"cargo bench --bench {bench_name}\",\n"
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
             \"p50_ns\": {}, \"p99_ns\": {}, \"ops_per_sec\": {:.0}}}{}\n",
            r.name,
            r.iters,
            r.mean_ns,
            r.p50_ns,
            r.p99_ns,
            r.ops_per_sec(),
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}
