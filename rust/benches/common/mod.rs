//! Minimal bench harness (criterion is not in the offline crate set):
//! warms up, runs timed iterations, reports mean / p50 / p99 and
//! throughput. Deterministic iteration counts for comparable runs.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
}

pub fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..iters.div_ceil(10).min(100) {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p99_ns: samples[samples.len() * 99 / 100],
    };
    println!(
        "{:<44} {:>9.0} ns/iter  p50 {:>9} ns  p99 {:>9} ns  ({} iters)",
        r.name, r.mean_ns, r.p50_ns, r.p99_ns, r.iters
    );
    r
}

/// One-shot timing for end-to-end experiment runs.
pub fn bench_once<F: FnOnce() -> u64>(name: &str, f: F) {
    let t0 = Instant::now();
    let rows = f();
    let wall = t0.elapsed().as_secs_f64();
    println!("{:<12} wall {:>8.2}s   ({} result rows)", name, wall, rows);
}
