//! End-to-end figure benchmarks: run each paper experiment at quick
//! scale and report wall time — one bench per table and figure (the
//! `flexswap <id>` CLI prints the actual rows).
//!
//! Run: `cargo bench --bench figures [fig-id ...]`

mod common;

use common::bench_once;
use flexswap::harness::{registry, Scale};

fn main() {
    // cargo bench passes flags like --bench; only bare ids filter.
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    println!("== flexswap figure benchmarks (quick scale) ==\n");
    for exp in registry() {
        if !filter.is_empty() && !filter.iter().any(|f| f == exp.id) {
            continue;
        }
        bench_once(exp.id, || {
            let tables = (exp.run)(Scale::Quick);
            tables.iter().map(|t| t.rows.len() as u64).sum::<u64>()
        });
    }
    println!("\n(rows regenerating each figure: `cargo run --release -- <fig-id>`)");
}
