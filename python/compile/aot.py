"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs only here, at build time; the Rust binary is self-contained
once ``artifacts/`` is populated.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_dt_reclaim(h: int, n: int) -> str:
    import functools
    import math

    from compile.kernels.coldstats import DEFAULT_BLOCK_N

    block_n = math.gcd(n, DEFAULT_BLOCK_N)
    fn = functools.partial(model.dt_reclaim, block_n=block_n)
    hist = jax.ShapeDtypeStruct((h, n), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(hist, scalar, scalar))


def lower_ert_victim(m: int) -> str:
    ert = jax.ShapeDtypeStruct((m,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(model.ert_victim).lower(ert, ert, scalar))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--history", type=int, default=model.DEFAULT_H)
    ap.add_argument("--pages", type=int, default=model.DEFAULT_N)
    ap.add_argument("--ert", type=int, default=model.DEFAULT_ERT_N)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    artifacts = {
        "dt_reclaim.hlo.txt": lower_dt_reclaim(args.history, args.pages),
        "ert_victim.hlo.txt": lower_ert_victim(args.ert),
    }
    for name, text in artifacts.items():
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars -> {path}")

    # Shape manifest the Rust runtime validates against at load time.
    manifest = {
        "dt_reclaim": {"history": args.history, "pages": args.pages},
        "ert_victim": {"entries": args.ert},
        "smoothing": model.SMOOTHING,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest -> {mpath}")


if __name__ == "__main__":
    main()
