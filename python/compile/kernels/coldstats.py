"""L1 Pallas kernel: per-page cold statistics over an access-bitmap history.

The dt-reclaimer (paper §5.4) maintains a ring of ``H`` access bitmaps
produced by the EPT scanner, one row per scan interval (row ``H-1`` is the
most recent scan).  For every page it needs, each interval:

* ``age``       — scans since the page was last seen accessed (0 = accessed
                  in the latest scan, ``H`` = not accessed in the window),
* ``count``     — number of scans in which the page was accessed,
* ``distance``  — the page's most recent *access distance*: the gap, in
                  scans, between its two most recent accesses (``H`` when the
                  page was accessed fewer than two times in the window).

This is the hot spot of the reclaimer's analytics: a single fused pass over
the ``[H, N]`` history.  The kernel tiles ``N`` into VMEM-resident blocks of
``block_n`` pages via ``BlockSpec`` so the whole history column for a block
is loaded exactly once (optimal HBM traffic on a real TPU; ``interpret=True``
here so the lowered HLO runs on the CPU PJRT client).

Bitmaps are carried as ``float32`` 0.0/1.0 — the natural dtype at the PJRT
boundary and what the VPU reduces natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["coldstats", "DEFAULT_H", "DEFAULT_N", "DEFAULT_BLOCK_N"]

# Shapes baked into the shipped artifact (rust tiles bigger VMs over calls).
DEFAULT_H = 32
DEFAULT_N = 65536
DEFAULT_BLOCK_N = 4096


def _coldstats_kernel(hist_ref, age_ref, cnt_ref, dist_ref, *, h: int):
    """One block: hist_ref is [H, B]; outputs are [B]."""
    hist = hist_ref[...]  # [H, B] of {0.0, 1.0}
    fh = jnp.float32(h)

    # Row index + 1 so that "never accessed" folds to 0 under max().
    idx = jax.lax.broadcasted_iota(jnp.float32, hist.shape, 0) + 1.0

    cnt = jnp.sum(hist, axis=0)  # [B]

    # Most recent access: the largest (index+1) with a set bit.
    last = jnp.max(hist * idx, axis=0)  # [B], 0.0 when never accessed
    age = jnp.where(last > 0.0, fh - last, fh)

    # Second most recent access: mask out the winning row, take max again.
    masked = jnp.where(idx == last[None, :], 0.0, hist * idx)
    last2 = jnp.max(masked, axis=0)
    dist = jnp.where(last2 > 0.0, last - last2, fh)

    age_ref[...] = age
    cnt_ref[...] = cnt
    dist_ref[...] = dist


@functools.partial(jax.jit, static_argnames=("block_n",))
def coldstats(hist: jax.Array, *, block_n: int = DEFAULT_BLOCK_N):
    """Compute (age, count, distance) for each page column of ``hist``.

    Args:
      hist: ``[H, N]`` float32 access-bitmap history, row ``H-1`` newest.
      block_n: pages per VMEM block; must divide ``N``.

    Returns:
      Tuple of three ``[N]`` float32 arrays ``(age, count, distance)``.
    """
    h, n = hist.shape
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide N={n}")
    grid = (n // block_n,)
    out_shape = jax.ShapeDtypeStruct((n,), jnp.float32)
    kernel = functools.partial(_coldstats_kernel, h=h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h, block_n), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=True,  # CPU-PJRT executable HLO; Mosaic only on real TPU
    )(hist)
