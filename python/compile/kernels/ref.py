"""Pure-numpy oracle for the Pallas kernels and the L2 pipeline.

Everything here is written in the most obvious way possible (python loops
where that is clearest) — this file is the correctness ground truth that
``pytest`` checks ``kernels.coldstats`` and ``compile.model`` against.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "coldstats_ref",
    "distance_histogram_ref",
    "proposed_threshold_ref",
    "dt_reclaim_ref",
    "ert_victim_ref",
]


def coldstats_ref(hist: np.ndarray):
    """Reference (age, count, distance) over a [H, N] 0/1 history matrix."""
    hist = np.asarray(hist, dtype=np.float64)
    h, n = hist.shape
    age = np.full(n, float(h))
    cnt = hist.sum(axis=0)
    dist = np.full(n, float(h))
    for p in range(n):
        rows = np.flatnonzero(hist[:, p] > 0.0)
        if rows.size >= 1:
            age[p] = (h - 1) - rows[-1]
        if rows.size >= 2:
            dist[p] = rows[-1] - rows[-2]
    return (
        age.astype(np.float32),
        cnt.astype(np.float32),
        dist.astype(np.float32),
    )


def distance_histogram_ref(dist: np.ndarray, cnt: np.ndarray, h: int):
    """Histogram of access distances over pages seen in the window.

    Bucket ``d`` (1..H-1) counts pages whose most recent access distance is
    ``d``; bucket ``H`` aggregates pages without a measurable distance (seen
    < 2 times in the window).  Bucket 0 is always empty (distance >= 1).
    """
    out = np.zeros(h + 1, dtype=np.float64)
    for d, c in zip(np.asarray(dist), np.asarray(cnt)):
        if c >= 1.0:  # page present in the window at all
            out[int(round(float(d)))] += 1.0
    return out.astype(np.float32)


def proposed_threshold_ref(histogram: np.ndarray, target_rate: float) -> float:
    """Smallest threshold t so that the predicted promotion rate <= target.

    A page reclaimed at age threshold ``t`` is predicted to fault again next
    interval iff its access distance is ``>= t``.  The predicted promotion
    rate for threshold ``t`` is therefore ``tail(t) / total`` over pages
    with a *measured* distance (bucket ``H`` — seen fewer than two times —
    is excluded; their distance is unknown).
    """
    histogram = np.asarray(histogram, dtype=np.float64)
    h = histogram.shape[0] - 1
    measured = histogram.copy()
    measured[h] = 0.0  # unknown-distance pages excluded (see model.py)
    measured[0] = 0.0
    total = measured.sum()
    if total <= 0.0:
        return float(h)
    tail = np.cumsum(measured[::-1])[::-1]  # tail[t] = sum_{d>=t}
    for t in range(1, h + 1):
        if tail[t] / total <= target_rate:
            return float(t)
    return float(h)


def dt_reclaim_ref(
    hist: np.ndarray,
    target_rate: float,
    prev_threshold: float,
    smoothing: float = 0.5,
):
    """Reference for the full L2 dt-reclaim analytics pipeline."""
    h = hist.shape[0]
    age, cnt, dist = coldstats_ref(hist)
    histogram = distance_histogram_ref(dist, cnt, h)
    proposed = proposed_threshold_ref(histogram, target_rate)
    smoothed = smoothing * prev_threshold + (1.0 - smoothing) * proposed
    return age, cnt, histogram, np.float32(proposed), np.float32(smoothed)


def ert_victim_ref(ert: np.ndarray, valid: np.ndarray, dt: float):
    """Reference for the SYS-R victim scorer.

    Returns (victim_index, victim_score, updated_ert).  The victim is the
    valid entry with the largest *absolute* estimated-reuse-time after the
    countdown by ``dt`` (paper §6.5); invalid entries can never win.
    """
    ert = np.asarray(ert, dtype=np.float32)
    valid = np.asarray(valid, dtype=np.float32)
    new = (ert - np.float32(dt) * valid).astype(np.float32)
    score = np.where(valid > 0.0, np.abs(new), -np.inf)
    idx = int(np.argmax(score))
    return idx, np.float32(score[idx]), new
