"""L2 compute graphs for the flexswap Memory Manager, built on the L1 kernel.

Two graphs are AOT-lowered to HLO text (see ``aot.py``) and executed from
the Rust coordinator via PJRT, always *off* the page-fault critical path:

* ``dt_reclaim``  — the dt-reclaimer analytics (paper §5.4): per-page
  age/count/distance (L1 Pallas kernel), the access-distance histogram,
  and the proposed + smoothed reclamation threshold for a target promotion
  rate.
* ``ert_victim``  — the SYS-R reuse-distance reclaimer's victim scorer
  (paper §6.5): count down the Estimated-Reuse-Time table and pick the
  valid entry with the largest absolute ERT.

All shapes are static (PJRT artifacts are monomorphic); the Rust side tiles
larger VMs over multiple invocations and merges the histograms.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.coldstats import (
    DEFAULT_BLOCK_N,
    DEFAULT_H,
    DEFAULT_N,
    coldstats,
)

__all__ = [
    "dt_reclaim",
    "ert_victim",
    "DEFAULT_H",
    "DEFAULT_N",
    "DEFAULT_ERT_N",
    "SMOOTHING",
]

DEFAULT_ERT_N = 65536
# Threshold smoothing factor (paper: "the final threshold is smoothed out
# from the current and past proposed thresholds").
SMOOTHING = 0.5


def distance_histogram(dist: jax.Array, cnt: jax.Array, h: int) -> jax.Array:
    """[H+1] histogram of access distances over pages seen in the window.

    Implemented as a one-hot matmul-style reduction so XLA lowers it to a
    single fused pass; bucket H collects pages seen fewer than two times.
    """
    seen = (cnt >= 1.0).astype(jnp.float32)  # [N]
    buckets = jnp.arange(h + 1, dtype=jnp.float32)  # [H+1]
    onehot = (dist[:, None] == buckets[None, :]).astype(jnp.float32)  # [N,H+1]
    return jnp.sum(onehot * seen[:, None], axis=0)


def proposed_threshold(histogram: jax.Array, target_rate: jax.Array) -> jax.Array:
    """Smallest t in 1..H-1 with tail-rate(t) <= target; H when none.

    Bucket H holds pages seen fewer than two times — their reuse
    distance is *unknown*, so they are excluded from the rate (counting
    them as distance-H would pin the threshold at H whenever cold pages
    exist, which is exactly backwards).
    """
    h = histogram.shape[0] - 1
    measured = histogram.at[h].set(0.0).at[0].set(0.0)
    total = jnp.sum(measured)
    # tail[t] = sum_{d >= t} measured[d]
    tail = jnp.cumsum(measured[::-1])[::-1]
    rate = tail / jnp.maximum(total, 1.0)
    t = jnp.arange(h + 1, dtype=jnp.float32)
    ok = (rate <= target_rate) & (t >= 1.0)
    candidate = jnp.where(ok, t, jnp.float32(h))
    proposed = jnp.min(candidate)
    return jnp.where(total > 0.0, proposed, jnp.float32(h))


@functools.partial(jax.jit, static_argnames=("block_n",))
def dt_reclaim(
    hist: jax.Array,
    target_rate: jax.Array,
    prev_threshold: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
):
    """Full dt-reclaimer analytics pipeline.

    Args:
      hist: ``[H, N]`` float32 access-bitmap history (row ``H-1`` newest).
      target_rate: scalar float32, target promotion rate (paper default 2%).
      prev_threshold: scalar float32, previous smoothed threshold.

    Returns:
      ``(age[N], count[N], histogram[H+1], proposed, smoothed)``.
    """
    h = hist.shape[0]
    age, cnt, dist = coldstats(hist, block_n=block_n)
    histogram = distance_histogram(dist, cnt, h)
    proposed = proposed_threshold(histogram, target_rate)
    smoothed = SMOOTHING * prev_threshold + (1.0 - SMOOTHING) * proposed
    return age, cnt, histogram, proposed, smoothed


@jax.jit
def ert_victim(ert: jax.Array, valid: jax.Array, dt: jax.Array):
    """SYS-R victim scan: countdown + argmax |ERT| over valid entries.

    Args:
      ert: ``[M]`` float32 estimated-reuse-time table (signed; counts down).
      valid: ``[M]`` float32 0/1 mask of live entries.
      dt: scalar float32 countdown to apply to live entries.

    Returns:
      ``(victim_index_f32, victim_score, updated_ert[M])``.
    """
    new = ert - dt * valid
    score = jnp.where(valid > 0.0, jnp.abs(new), -jnp.inf)
    idx = jnp.argmax(score)
    return idx.astype(jnp.float32), score[idx], new
