"""L2 pipeline (dt_reclaim, ert_victim) vs numpy oracle + invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels.ref import (
    dt_reclaim_ref,
    ert_victim_ref,
    proposed_threshold_ref,
)


def run_dt(hist, target, prev, block_n):
    age, cnt, histogram, proposed, smoothed = model.dt_reclaim(
        np.asarray(hist, dtype=np.float32),
        np.float32(target),
        np.float32(prev),
        block_n=block_n,
    )
    return (
        np.asarray(age),
        np.asarray(cnt),
        np.asarray(histogram),
        float(proposed),
        float(smoothed),
    )


@pytest.mark.parametrize("target", [0.0, 0.02, 0.3, 1.0])
def test_dt_reclaim_matches_ref(target):
    rng = np.random.default_rng(42)
    hist = (rng.random((16, 64)) < 0.35).astype(np.float32)
    age, cnt, histogram, proposed, smoothed = run_dt(hist, target, 5.0, 64)
    rage, rcnt, rhist, rprop, rsmooth = dt_reclaim_ref(hist, target, 5.0)
    np.testing.assert_allclose(age, rage)
    np.testing.assert_allclose(cnt, rcnt)
    np.testing.assert_allclose(histogram, rhist)
    assert proposed == pytest.approx(float(rprop))
    assert smoothed == pytest.approx(float(rsmooth))


def test_threshold_monotonic_in_target():
    """Higher tolerated promotion rate => lower (more aggressive) threshold."""
    rng = np.random.default_rng(3)
    hist = (rng.random((24, 128)) < 0.25).astype(np.float32)
    thresholds = [
        run_dt(hist, t, 10.0, 128)[3] for t in (0.0, 0.01, 0.05, 0.2, 1.0)
    ]
    assert thresholds == sorted(thresholds, reverse=True)


def test_threshold_empty_history_is_max():
    hist = np.zeros((8, 32), dtype=np.float32)
    _, _, histogram, proposed, _ = run_dt(hist, 0.02, 2.0, 32)
    assert histogram.sum() == 0.0
    assert proposed == 8.0


def test_histogram_counts_pages_seen():
    """Histogram mass equals the number of pages seen in the window."""
    rng = np.random.default_rng(11)
    hist = (rng.random((16, 96)) < 0.4).astype(np.float32)
    _, cnt, histogram, _, _ = run_dt(hist, 0.02, 1.0, 96)
    assert histogram.sum() == pytest.approx(float((cnt >= 1).sum()))


def test_target_rate_semantics():
    """Tail rate at the proposed threshold does not exceed the target."""
    rng = np.random.default_rng(5)
    hist = (rng.random((32, 256)) < 0.3).astype(np.float32)
    target = 0.1
    _, _, histogram, proposed, _ = run_dt(hist, target, 4.0, 256)
    h = histogram.shape[0] - 1
    t = int(proposed)
    if t < h:
        tail = histogram[t:].sum()
        assert tail / histogram.sum() <= target + 1e-6


@settings(max_examples=30, deadline=None)
@given(
    h=st.integers(min_value=2, max_value=16),
    n=st.sampled_from([16, 32, 64]),
    p=st.floats(min_value=0.05, max_value=0.95),
    target=st.floats(min_value=0.0, max_value=1.0),
    prev=st.floats(min_value=0.0, max_value=32.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dt_hypothesis(h, n, p, target, prev, seed):
    rng = np.random.default_rng(seed)
    hist = (rng.random((h, n)) < p).astype(np.float32)
    age, cnt, histogram, proposed, smoothed = run_dt(hist, target, prev, n)
    rage, rcnt, rhist, rprop, rsmooth = dt_reclaim_ref(hist, target, prev)
    np.testing.assert_allclose(age, rage)
    np.testing.assert_allclose(cnt, rcnt)
    np.testing.assert_allclose(histogram, rhist)
    assert proposed == pytest.approx(float(rprop))
    assert smoothed == pytest.approx(float(rsmooth), abs=1e-5)


def run_ert(ert, valid, dt):
    idx, score, new = model.ert_victim(
        np.asarray(ert, np.float32), np.asarray(valid, np.float32), np.float32(dt)
    )
    return int(idx), float(score), np.asarray(new)


def test_ert_victim_basic():
    ert = np.array([3.0, -10.0, 5.0, 1.0], dtype=np.float32)
    valid = np.array([1.0, 1.0, 1.0, 1.0], dtype=np.float32)
    idx, score, new = run_ert(ert, valid, 0.0)
    assert idx == 1 and score == 10.0
    np.testing.assert_allclose(new, ert)


def test_ert_victim_skips_invalid():
    ert = np.array([3.0, -100.0, 5.0], dtype=np.float32)
    valid = np.array([1.0, 0.0, 1.0], dtype=np.float32)
    idx, _, new = run_ert(ert, valid, 2.0)
    assert idx == 2
    np.testing.assert_allclose(new, [1.0, -100.0, 3.0])  # countdown only live


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=64),
    dt=st.floats(min_value=0.0, max_value=100.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ert_hypothesis(m, dt, seed):
    rng = np.random.default_rng(seed)
    ert = rng.normal(0, 50, m).astype(np.float32)
    valid = (rng.random(m) < 0.7).astype(np.float32)
    idx, score, new = run_ert(ert, valid, dt)
    ridx, rscore, rnew = ert_victim_ref(ert, valid, dt)
    np.testing.assert_allclose(new, rnew, rtol=1e-6)
    if valid.sum() > 0:
        # Argmax ties may differ; scores must match.
        assert score == pytest.approx(float(rscore), rel=1e-6)
        assert valid[idx] == 1.0


def test_proposed_threshold_ref_selfcheck():
    hist = np.array([0, 5, 3, 2, 0], dtype=np.float32)  # H = 4
    # total 10; tail(1)=10(1.0) tail(2)=5(0.5) tail(3)=2(0.2) tail(4)=0
    assert proposed_threshold_ref(hist, 1.0) == 1.0
    assert proposed_threshold_ref(hist, 0.5) == 2.0
    assert proposed_threshold_ref(hist, 0.3) == 3.0
    assert proposed_threshold_ref(hist, 0.0) == 4.0
