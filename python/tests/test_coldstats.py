"""L1 Pallas kernel vs pure-numpy oracle (the core correctness signal)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.coldstats import coldstats
from compile.kernels.ref import coldstats_ref


def random_hist(rng, h, n, p):
    return (rng.random((h, n)) < p).astype(np.float32)


@pytest.mark.parametrize("h", [1, 2, 3, 8, 32])
@pytest.mark.parametrize("n", [8, 64, 256])
def test_matches_ref_shapes(h, n):
    rng = np.random.default_rng(h * 1000 + n)
    hist = random_hist(rng, h, n, 0.3)
    age, cnt, dist = coldstats(hist, block_n=n)
    rage, rcnt, rdist = coldstats_ref(hist)
    np.testing.assert_allclose(age, rage)
    np.testing.assert_allclose(cnt, rcnt)
    np.testing.assert_allclose(dist, rdist)


@pytest.mark.parametrize("blocks", [1, 2, 4, 8])
def test_tiling_invariance(blocks):
    """Block size must not change results (pure data-parallel kernel)."""
    rng = np.random.default_rng(7)
    hist = random_hist(rng, 16, 128, 0.4)
    base = coldstats(hist, block_n=128)
    tiled = coldstats(hist, block_n=128 // blocks)
    for a, b in zip(base, tiled):
        np.testing.assert_allclose(a, b)


def test_never_accessed_page():
    hist = np.zeros((8, 16), dtype=np.float32)
    age, cnt, dist = coldstats(hist, block_n=16)
    assert (np.asarray(age) == 8.0).all()
    assert (np.asarray(cnt) == 0.0).all()
    assert (np.asarray(dist) == 8.0).all()


def test_accessed_every_scan():
    hist = np.ones((8, 16), dtype=np.float32)
    age, cnt, dist = coldstats(hist, block_n=16)
    assert (np.asarray(age) == 0.0).all()
    assert (np.asarray(cnt) == 8.0).all()
    assert (np.asarray(dist) == 1.0).all()


def test_single_access_has_no_distance():
    hist = np.zeros((8, 4), dtype=np.float32)
    hist[3, 1] = 1.0
    age, cnt, dist = coldstats(hist, block_n=4)
    assert np.asarray(age)[1] == 4.0  # rows 4..7 after the access
    assert np.asarray(cnt)[1] == 1.0
    assert np.asarray(dist)[1] == 8.0  # H sentinel: seen < 2 times


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(min_value=1, max_value=24),
    nblocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([4, 16, 32]),
    p=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_sweep(h, nblocks, block, p, seed):
    rng = np.random.default_rng(seed)
    hist = random_hist(rng, h, nblocks * block, p)
    age, cnt, dist = coldstats(hist, block_n=block)
    rage, rcnt, rdist = coldstats_ref(hist)
    np.testing.assert_allclose(age, rage)
    np.testing.assert_allclose(cnt, rcnt)
    np.testing.assert_allclose(dist, rdist)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_dtype_robustness(seed):
    """Kernel accepts float32 histories produced from any integer bitmap."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(8, 32), dtype=np.int64)
    for dtype in (np.float32, np.int32, np.uint8, np.bool_):
        hist = bits.astype(dtype).astype(np.float32)
        age, cnt, dist = coldstats(hist, block_n=32)
        rage, rcnt, rdist = coldstats_ref(hist)
        np.testing.assert_allclose(age, rage)
        np.testing.assert_allclose(cnt, rcnt)
        np.testing.assert_allclose(dist, rdist)
