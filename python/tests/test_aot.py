"""AOT lowering sanity: artifacts must be valid HLO text with stable entry."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def small_dt_hlo():
    return aot.lower_dt_reclaim(h=8, n=256)


def test_dt_reclaim_lowers_to_hlo(small_dt_hlo):
    assert "HloModule" in small_dt_hlo
    assert "ENTRY" in small_dt_hlo
    # inputs: hist [8,256] + two scalars
    assert "f32[8,256]" in small_dt_hlo


def test_ert_victim_lowers_to_hlo():
    text = aot.lower_ert_victim(m=128)
    assert "HloModule" in text
    assert "f32[128]" in text


def test_manifest_roundtrip(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--history", "4",
                "--pages", "64", "--ert", "32"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["dt_reclaim"] == {"history": 4, "pages": 64}
    assert manifest["ert_victim"] == {"entries": 32}
    for name in ("dt_reclaim.hlo.txt", "ert_victim.hlo.txt"):
        assert "HloModule" in (tmp_path / name).read_text()


def test_default_shapes_exported():
    assert model.DEFAULT_H == 32
    assert model.DEFAULT_N == 65536
    assert model.DEFAULT_ERT_N == 65536
